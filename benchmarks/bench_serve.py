"""Serving refresh bench: warm-vs-cold accounting + lookup latency.

``PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--out PATH]``

The paper's deployment story (§6, "called on a daily basis") in
numbers, via the generation engine (repro/serve/): a multi-day scenario
of deterministic budget perturbations where every generation is solved
twice — warm-started from the previous generation's multipliers (the
engine's path) and cold from all-ones (the reference) — plus on-demand
lookup throughput through the DecisionService chunk cache.

What the report claims, and how it is gated:

* **Warm iteration counts are the hardware-independent number**: the
  solve is deterministic for a pinned virtual-slot count (the bench
  pins ``slots=8`` on whatever device count is present — the host-fed
  driver is bitwise mesh-size-invariant), so the per-generation
  warm/cold iteration table reproduces everywhere. The bench itself
  exits 1 unless warm beats cold in *total* iterations over the
  scenario and every lookup round-trips bitwise against
  ``decisions_chunk`` materialisation; ``tools/bench_diff.py`` then
  gates the committed cold/warm ratio against CI's measurement.
* **Lookup QPS is recorded, not gated** — wall clock on shared CPU is
  noisy; the cache hit-rate accounting next to it is deterministic.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.core import SolverConfig  # noqa: E402
from repro.launch.refresh import run_scenario  # noqa: E402
from repro.serve import WorkloadSpec  # noqa: E402

K, Q, SLOTS = 8, 2, 8
# (n, chunk, generations): the smoke point is shared with CI so
# bench_diff can match points by n against the committed report.
GRID = [(16384, 1024, 4), (65536, 4096, 6)]
SMOKE_GRID = [(16384, 1024, 4)]


def bench_point(n, chunk, generations, seed=0, max_iters=60):
    spec = WorkloadSpec(seed=seed, n=n, k=K, chunk=chunk, q=Q,
                        tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=max_iters,
                       checkpoint_every=0)
    with tempfile.TemporaryDirectory(prefix="bench_serve_") as root:
        out = run_scenario(spec, generations, root, cfg, mesh=None,
                           slots=SLOTS, lookups=512, verify=True)
    return {
        "n": n, "chunk": chunk, "generations": generations,
        "k": K, "q": Q, "slots": SLOTS,
        "per_generation": out["per_generation"],
        "warm_iters_total": out["warm_iters_total"],
        "cold_iters_total": out["cold_iters_total"],
        "cold_over_warm": out["cold_over_warm"],
        "lookup": out["lookup"],
        "lookups_bitwise": out["lookups_bitwise"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small point (CI-friendly)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    points = []
    print("n,generations,warm_total,cold_total,cold/warm,batched_qps")
    for n, chunk, generations in (SMOKE_GRID if args.smoke else GRID):
        p = bench_point(n, chunk, generations)
        points.append(p)
        print(f"{n},{generations},{p['warm_iters_total']},"
              f"{p['cold_iters_total']},{p['cold_over_warm']},"
              f"{p['lookup']['batched_qps']}")

    report = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "points": points,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    bad = [p["n"] for p in points
           if p["warm_iters_total"] >= p["cold_iters_total"]
           or not p["lookups_bitwise"]]
    if bad:
        print(f"REGRESSION: warm did not beat cold (or lookup mismatch) "
              f"at n={bad}")
        sys.exit(1)


if __name__ == "__main__":
    main()
