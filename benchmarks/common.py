"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name, seconds, **derived):
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{seconds * 1e6:.1f},{extra}")
