"""Benchmark driver: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,...]``
prints ``name,us_per_call,derived`` CSV rows. The roofline section reads
reports/dryrun_full.json when present (produced by launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from benchmarks import paper_tables  # noqa: E402


SECTIONS = {
    "fig1": paper_tables.fig1_optimality,
    "tab1": paper_tables.tab1_duality,
    "tab2": paper_tables.tab2_presolve,
    "fig2": paper_tables.fig2_scaling_n,
    "fig3": paper_tables.fig3_scaling_k,
    "fig4": paper_tables.fig4_speedup,
    "fig56": paper_tables.fig56_dd_vs_scd,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SECTIONS))
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-friendly)")
    args = ap.parse_args()

    picks = args.only.split(",") if args.only else list(SECTIONS)
    print("name,us_per_call,derived")
    quick = {
        "fig1": lambda: paper_tables.fig1_optimality(n=300, ks=(1, 5, 10)),
        "tab1": lambda: paper_tables.tab1_duality(n=20_000, ms=(1, 5, 10)),
        "tab2": lambda: paper_tables.tab2_presolve(ns=(100_000,)),
        "fig2": lambda: paper_tables.fig2_scaling_n(ns=(50_000, 100_000, 200_000)),
        "fig3": lambda: paper_tables.fig3_scaling_k(ks=(4, 10, 20), n=50_000),
        "fig4": lambda: paper_tables.fig4_speedup(n=5_000),
        "fig56": lambda: paper_tables.fig56_dd_vs_scd(n=5_000),
    }
    for name in picks:
        fn = quick[name] if args.quick else SECTIONS[name]
        fn()

    # roofline summary (if the dry-run report exists)
    report = pathlib.Path("reports/dryrun_full.json")
    if report.exists():
        from benchmarks import roofline
        rows = roofline.analyse(str(report))
        ok = [r for r in rows if r.get("status") == "ok"]
        if ok:
            worst = min(ok, key=lambda r: r["mfu_proxy"])
            best = max(ok, key=lambda r: r["mfu_proxy"])
            print(f"roofline/cells_ok,{len(ok)},of={len(rows)}")
            print(f"roofline/best,{best['mfu_proxy']*100:.1f}%,"
                  f"cell={best['arch']}/{best['shape']}/{best['mesh']}")
            print(f"roofline/worst,{worst['mfu_proxy']*100:.1f}%,"
                  f"cell={worst['arch']}/{worst['shape']}/{worst['mesh']}")


if __name__ == "__main__":
    main()
