"""§Perf summary: paper-faithful baseline vs optimized sharding, per cell.

Reads the baseline sweep (reports/dryrun_full.json) and the variant runs
(reports/hc_*.json, reports/opt_*.json) and prints the before/after table
embedded in EXPERIMENTS.md. Run after launch/dryrun.py variants exist.
"""
from __future__ import annotations

import glob
import json
import pathlib

from benchmarks.analytic import cell_terms
from benchmarks.roofline import ICI, PEAK, corrected, model_flops_per_chip


def _terms(rec, fsdp_mode, chips=None):
    from repro.configs import registry
    from repro.models import model as M

    chips = chips or (512 if rec["mesh"] == "2x16x16" else 256)
    cfg = registry.get(rec["arch"])
    cell = M.SHAPES[rec["shape"]]
    _, _, co = corrected(rec)
    ana = cell_terms(cfg, cell, rec["n_params"], chips, fsdp_mode=fsdp_mode)
    t = dict(compute=ana.compute_s(), memory=ana.memory_s(),
             collective=co / ICI)
    mf = model_flops_per_chip(
        cfg, {"kind": cell.kind, "global_batch": cell.global_batch,
              "text_len": M._text_len(cfg, cell.seq_len)},
        rec["n_params"], chips)
    dom = max(t.values())
    t["bound"] = max(t, key=t.get)
    t["step_lb"] = dom
    t["mfu"] = (mf / PEAK) / dom if dom else 0.0
    return t


def load_variants():
    out = {}
    for f in glob.glob("reports/hc_*_dpfull.json") + glob.glob("reports/opt_*.json"):
        rec = json.load(open(f))[0]
        if rec.get("status") != "ok":
            continue
        mode = rec.get("fsdp_mode", "full")
        out[(rec["arch"], rec["shape"], rec["mesh"])] = (rec, mode)
    return out


def main():
    base = {
        (r["arch"], r["shape"], r["mesh"]): r
        for r in json.load(open("reports/dryrun_full.json"))
        if r["status"] == "ok"
    }
    variants = load_variants()
    rows = ["| arch | shape | mesh | baseline bound / step-LB / MFU | optimized (mode) bound / step-LB / MFU | step-LB gain |",
            "|---|---|---|---|---|---|"]
    for key, (rec, mode) in sorted(variants.items()):
        if key not in base:
            continue
        b = _terms(base[key], "full")
        o = _terms(rec, mode)
        gain = b["step_lb"] / o["step_lb"] if o["step_lb"] else float("inf")
        rows.append(
            f"| {key[0]} | {key[1]} | {key[2]} "
            f"| {b['bound']} / {b['step_lb']:.3g}s / {b['mfu']*100:.1f}% "
            f"| ({mode}) {o['bound']} / {o['step_lb']:.3g}s / {o['mfu']*100:.1f}% "
            f"| **{gain:.1f}×** |")
    print("\n".join(rows))
    pathlib.Path("reports/perf_summary.md").write_text("\n".join(rows) + "\n")


if __name__ == "__main__":
    main()
