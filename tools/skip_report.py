"""Skipped-test report + anti-skip gate for the tier-1 CI job.

``python tools/skip_report.py PYTEST_JUNIT_XML [--fail-on PATTERN]``

Parses a pytest ``--junitxml`` report and prints a GitHub-flavoured
markdown summary (append it to ``$GITHUB_STEP_SUMMARY``): total /
passed / failed / skipped counts and one line per skipped test with its
reason. Exit status 1 when any skip reason matches ``--fail-on``
(default: ``hypothesis``) — the anti-skip gate: the property suites
must *run* in CI, and the ``_hypothesis_compat`` shim silently
downgrading them to skips (hypothesis missing from the image) has to
fail the job loudly, not render as green.
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys
import xml.etree.ElementTree as ET


def collect(xml_path):
    """Return (counts dict, [(test id, skip reason), ...])."""
    root = ET.parse(xml_path).getroot()
    suites = root.iter("testsuite")
    total = failed = errors = skipped = 0
    skips = []
    for suite in suites:
        total += int(suite.get("tests", 0))
        failed += int(suite.get("failures", 0))
        errors += int(suite.get("errors", 0))
        skipped += int(suite.get("skipped", 0))
        for case in suite.iter("testcase"):
            sk = case.find("skipped")
            if sk is not None:
                test_id = f"{case.get('classname')}::{case.get('name')}"
                skips.append((test_id, sk.get("message") or ""))
    passed = total - failed - errors - skipped
    return ({"total": total, "passed": passed, "failed": failed + errors,
             "skipped": skipped}, skips)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("junit_xml")
    ap.add_argument("--fail-on", default="hypothesis",
                    help="regex; a skip reason matching it fails the gate "
                         "(empty string disables)")
    args = ap.parse_args()
    counts, skips = collect(pathlib.Path(args.junit_xml))

    print("### Tier-1 test summary")
    print()
    print("| total | passed | failed | skipped |")
    print("|---|---|---|---|")
    print(f"| {counts['total']} | {counts['passed']} "
          f"| {counts['failed']} | {counts['skipped']} |")
    if skips:
        print()
        print("<details><summary>Skipped tests</summary>")
        print()
        for test_id, reason in skips:
            print(f"- `{test_id}` — {reason}")
        print()
        print("</details>")

    if args.fail_on:
        gated = [(t, r) for t, r in skips
                 if re.search(args.fail_on, r, re.IGNORECASE)]
        if gated:
            print()
            print(f"**ANTI-SKIP GATE**: {len(gated)} test(s) skipped for a "
                  f"reason matching {args.fail_on!r} — these must run in CI.")
            for t, r in gated:
                print(f"  GATED SKIP: {t} — {r}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
