"""Per-phase wall-time summary for obs trace journals.

``python tools/trace_view.py TRACE [TRACE...] [--assert-phases a,b,c]``

Each ``TRACE`` is either a ``*.jsonl`` span journal written by
:class:`repro.obs.Tracer` or a directory (a run root or its ``obs/``
subdirectory) whose journals are collected recursively. Journals are
read with the torn-tail-tolerant reader — a SIGKILLed writer's last
partial line is skipped, mid-file corruption is a hard error.

The summary groups spans by phase: count, total/mean/max duration and
the share of the summed wall time. ``--assert-phases`` turns the viewer
into a CI gate: a comma-separated phase list that must all appear in
the collected spans, exiting 1 (with the missing names) otherwise —
the cheap "did the instrumentation actually fire" check layered under
the bench parity gate.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.obs import read_trace  # noqa: E402


def collect(paths):
    """All span records from files/directories, with journal count."""
    files = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.jsonl")))
        else:
            files.append(p)
    spans = []
    for f in files:
        spans.extend(read_trace(f))
    return spans, len(files)


def summarise(spans):
    """phase -> {count, total_s, mean_s, max_s} over span records."""
    by_phase = {}
    for s in spans:
        d = by_phase.setdefault(s["phase"],
                                {"count": 0, "total_s": 0.0, "max_s": 0.0})
        dur = float(s.get("dur_s", 0.0))
        d["count"] += 1
        d["total_s"] += dur
        d["max_s"] = max(d["max_s"], dur)
    for d in by_phase.values():
        d["mean_s"] = d["total_s"] / d["count"]
    return by_phase


def render(by_phase) -> str:
    grand = sum(d["total_s"] for d in by_phase.values()) or 1.0
    lines = [f"{'phase':<18} {'count':>7} {'total_s':>10} "
             f"{'mean_s':>10} {'max_s':>10} {'share':>7}"]
    for phase in sorted(by_phase, key=lambda p: -by_phase[p]["total_s"]):
        d = by_phase[phase]
        lines.append(f"{phase:<18} {d['count']:>7} {d['total_s']:>10.4f} "
                     f"{d['mean_s']:>10.6f} {d['max_s']:>10.6f} "
                     f"{d['total_s'] / grand:>6.1%}")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("traces", nargs="+",
                    help="trace journal files or directories")
    ap.add_argument("--assert-phases", default=None,
                    help="comma-separated phases that must appear "
                         "(exit 1 on any missing)")
    args = ap.parse_args()

    spans, nfiles = collect(args.traces)
    by_phase = summarise(spans)
    print(f"{len(spans)} spans from {nfiles} journal(s)")
    if by_phase:
        print(render(by_phase))

    if args.assert_phases:
        want = [p.strip() for p in args.assert_phases.split(",")
                if p.strip()]
        missing = [p for p in want if p not in by_phase]
        if missing:
            print(f"MISSING phases: {', '.join(missing)}", file=sys.stderr)
            return 1
        print(f"all {len(want)} asserted phases present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
