"""Docs gate for CI: markdown link integrity + public-API docstrings.

    python tools/check_docs.py

Two checks, no dependencies beyond the stdlib:

1. Every relative markdown link ``[text](path)`` in the repo's *.md files
   must point at a file or directory that exists (http(s)/mailto and
   pure #anchor links are skipped; a path's own #fragment is ignored).
2. Every public module / class / function (name not starting with ``_``)
   in the public-API modules listed below must carry a docstring —
   checked by AST walk, so nothing is imported.

Exits non-zero listing every violation.
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# The documented public surface: shapes, sharding expectations and the
# chunked-vs-unchunked contract live in these docstrings.
PUBLIC_API = [
    "src/repro/core/solver.py",
    "src/repro/core/chunked.py",
    "src/repro/core/prefetch.py",
    "src/repro/core/bucketing.py",
    "src/repro/core/postprocess.py",
    "src/repro/core/types.py",
    "src/repro/core/sparse_scd.py",
    "src/repro/core/heartbeat.py",
    "src/repro/kernels/__init__.py",
    "src/repro/kernels/ops.py",
    "src/repro/launch/solve.py",
    "src/repro/launch/env.py",
    "src/repro/launch/supervisor.py",
    "src/repro/data/synth.py",
]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_markdown_links() -> list:
    """All relative links in tracked *.md files resolve to real paths."""
    errors = []
    for md in sorted(REPO.rglob("*.md")):
        if ".git" in md.parts:
            continue
        for m in _LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            if not (md.parent / path).exists() and not (REPO / path).exists():
                errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def _missing_docstrings(tree, path) -> list:
    errors = []
    if not ast.get_docstring(tree):
        errors.append(f"{path}: missing module docstring")
    # Module-level defs and class-body methods only: nested closures are
    # implementation detail, not API surface.
    defs = [n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef))]
    for cls in [n for n in defs if isinstance(n, ast.ClassDef)]:
        if cls.name.startswith("_"):
            continue        # a private class's methods are not API
        defs.extend(n for n in cls.body
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)))
    for node in defs:
        if node.name.startswith("_"):
            continue
        if not ast.get_docstring(node):
            errors.append(f"{path}:{node.lineno}: public "
                          f"{type(node).__name__.replace('Def', '').lower()} "
                          f"'{node.name}' missing docstring")
    return errors


def check_docstrings() -> list:
    """Every public name in PUBLIC_API modules has a docstring."""
    errors = []
    for rel in PUBLIC_API:
        path = REPO / rel
        if not path.exists():
            errors.append(f"{rel}: listed in PUBLIC_API but missing")
            continue
        tree = ast.parse(path.read_text(encoding="utf-8"))
        errors.extend(_missing_docstrings(tree, rel))
    return errors


def main() -> int:
    """Run both checks; print violations; return process exit code."""
    errors = check_markdown_links() + check_docstrings()
    for e in errors:
        print(e)
    print(f"docs check: {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
