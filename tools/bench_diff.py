"""Bench-regression smoke gate for the streamed solve.

``python tools/bench_diff.py COMMITTED CURRENT [--tol 0.25]``

Compares a freshly-measured ``BENCH_stream_passes.json`` (the CI smoke
run) against the committed one, matching points by ``n``:

* **Pass counts must match exactly** — they are deterministic (§5c
  accounting: iters + 1 fused, iters + 3 legacy), so any drift means a
  pass was silently reintroduced. This is the robust half of the gate.
* **Wall time must not regress more than ``--tol``** (default 25%) on
  the end-to-end streamed-solve configurations (device fused, host
  double-buffered fused). Wall comparisons across machines are noisy —
  hence the generous tolerance — but a fused finalize or prefetch
  pipeline that quietly serialises shows up far above it. Iteration
  counts are checked first: if they differ (e.g. a jax upgrade changed
  convergence), wall comparison is skipped for that point with a
  warning, since the solves are no longer like for like.

Exit status 1 on any violation; the messages name the offending point.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# (section, config) pairs whose wall time is gated; every config's pass
# count is checked regardless.
WALL_GATED = [("device", "fused"), ("host", "double_buffered_fused")]


def _points_by_n(report):
    return {p["n"]: p for p in report.get("points", [])}


def diff(committed: dict, current: dict, tol: float) -> list:
    """Return a list of human-readable violations (empty = gate passes)."""
    problems = []
    base = _points_by_n(committed)
    new = _points_by_n(current)
    shared = sorted(set(base) & set(new))
    if not shared:
        return [f"no shared n between committed {sorted(base)} and "
                f"current {sorted(new)}"]
    for n in shared:
        for section in ("device", "host"):
            for config, entry in new[n][section].items():
                if not isinstance(entry, dict):
                    continue
                ref = base[n][section].get(config)
                if ref is None:
                    continue
                if entry["passes"] != ref["passes"]:
                    if entry["iterations"] == ref["iterations"]:
                        problems.append(
                            f"n={n} {section}/{config}: source passes "
                            f"{ref['passes']} -> {entry['passes']} at equal "
                            f"iteration count (a pass was reintroduced?)")
                    else:
                        print(f"note: n={n} {section}/{config} iterations "
                              f"{ref['iterations']} -> {entry['iterations']};"
                              f" pass/wall comparison skipped")
                        continue
                if (section, config) in WALL_GATED:
                    if entry["iterations"] != ref["iterations"]:
                        print(f"note: n={n} {section}/{config} iteration "
                              f"count changed; wall comparison skipped")
                        continue
                    if entry["wall_s"] > ref["wall_s"] * (1.0 + tol):
                        problems.append(
                            f"n={n} {section}/{config}: wall "
                            f"{ref['wall_s']}s -> {entry['wall_s']}s "
                            f"(> {tol:.0%} regression)")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("committed", help="committed BENCH_stream_passes.json")
    ap.add_argument("current", help="freshly measured report to check")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed wall-time regression fraction")
    args = ap.parse_args()
    committed = json.loads(pathlib.Path(args.committed).read_text())
    current = json.loads(pathlib.Path(args.current).read_text())
    problems = diff(committed, current, args.tol)
    for p in problems:
        print(f"BENCH REGRESSION: {p}")
    if problems:
        sys.exit(1)
    print(f"bench_diff: ok ({args.committed} vs {args.current}, "
          f"tol {args.tol:.0%})")


if __name__ == "__main__":
    main()
