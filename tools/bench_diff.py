"""Bench-regression smoke gate for the streamed solve and the serve loop.

``python tools/bench_diff.py COMMITTED CURRENT [--tol 0.25]``

The report kind is auto-detected. For screening reports
(``BENCH_screening.json``, tagged ``"bench": "screening"``), the
screened solve must be bitwise-identical to the unscreened oracle,
stream no more items than it, keep its deterministic streamed-chunk
profile at equal iteration counts, and keep the items-reduction ratio
within ``--tol`` of the committed report. For serve reports
(``BENCH_serve.json``, tagged ``"bench": "serve"``), points are matched
by ``n`` and the **cold/warm iteration ratio** — the paper's daily-call
warm-start payoff — must not shrink by more than ``--tol`` against the
committed report, with warm strictly beating cold either way; lookup
QPS is informational (wall noise). When the warm AND cold totals both
match the committed point exactly (they are deterministic at a pinned
slot count), the ratio check is trivially satisfied and any drift in
either total is reported as a note. For front reports
(``BENCH_front.json``, tagged ``"bench": "front"``), bitwise HTTP
answer parity and the diff endpoint's deterministic chunk-fill profile
are absolute, and sustained batched QPS is gated within ``--tol``. For
obs reports (``BENCH_obs.json``, tagged ``"bench": "obs"``), bitwise
obs-on/off parity and the expected span counts are absolute, and the
enabled-path overhead fraction is gated within an *additive* ``--tol``
of the committed measurement.

Otherwise the report is a ``BENCH_stream_passes.json`` (the CI smoke
run) compared against the committed one, matching points by ``n``:

* **Pass counts must match exactly** — they are deterministic (§5c
  accounting: iters + 1 fused, iters + 3 legacy), so any drift means a
  pass was silently reintroduced. This is the robust half of the gate.
* **Wall time must not regress more than ``--tol``** (default 25%) on
  the end-to-end streamed-solve configurations (device fused, host
  double-buffered fused). Wall comparisons across machines are noisy —
  hence the generous tolerance — but a fused finalize or prefetch
  pipeline that quietly serialises shows up far above it. Iteration
  counts are checked first: if they differ (e.g. a jax upgrade changed
  convergence), wall comparison is skipped for that point with a
  warning, since the solves are no longer like for like.

Exit status 1 on any violation; the messages name the offending point.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# (section, config) pairs whose wall time is gated; every config's pass
# count is checked regardless.
WALL_GATED = [("device", "fused"), ("host", "double_buffered_fused")]


def _points_by_n(report):
    return {p["n"]: p for p in report.get("points", [])}


def diff_serve(committed: dict, current: dict, tol: float) -> list:
    """Serve-report violations: the cold/warm ratio is the gated claim."""
    problems = []
    base = _points_by_n(committed)
    new = _points_by_n(current)
    shared = sorted(set(base) & set(new))
    if not shared:
        return [f"no shared n between committed {sorted(base)} and "
                f"current {sorted(new)}"]
    for n in shared:
        ref, cur = base[n], new[n]
        if cur["warm_iters_total"] >= cur["cold_iters_total"]:
            problems.append(
                f"n={n}: warm refreshes no longer beat cold "
                f"({cur['warm_iters_total']} >= {cur['cold_iters_total']} "
                "total iterations)")
            continue
        if (cur["warm_iters_total"] != ref["warm_iters_total"]
                or cur["cold_iters_total"] != ref["cold_iters_total"]):
            print(f"note: n={n} iteration totals moved "
                  f"warm {ref['warm_iters_total']} -> "
                  f"{cur['warm_iters_total']}, cold "
                  f"{ref['cold_iters_total']} -> {cur['cold_iters_total']}"
                  " (ratio still gated)")
        if cur["cold_over_warm"] < ref["cold_over_warm"] * (1.0 - tol):
            problems.append(
                f"n={n}: cold/warm iteration ratio "
                f"{ref['cold_over_warm']} -> {cur['cold_over_warm']} "
                f"(warm-start payoff shrank > {tol:.0%})")
        if not cur.get("lookups_bitwise", True):
            problems.append(f"n={n}: lookups no longer bitwise-equal to "
                            "materialisation")
    return problems


def diff_front(committed: dict, current: dict, tol: float) -> list:
    """Front-report violations: bitwise parity and the diff endpoint's
    pass accounting are absolute; sustained batched QPS is wall-gated.

    Parity covers every HTTP-answered row against the materialisation
    of the generation that answered it, plus the cross-generation diff
    against brute force. The diff's chunk-fill profile is
    deterministic — first call against a baseline costs exactly one
    grouped pass (``chunks`` fills on the baseline), repeats cost zero
    on both cached generations — so any drift is a violation. QPS
    crosses process + HTTP boundaries and is noisy, hence the generous
    ``tol`` (same convention as the wall-gated stream configs)."""
    problems = []
    base = _points_by_n(committed)
    new = _points_by_n(current)
    shared = sorted(set(base) & set(new))
    if not shared:
        return [f"no shared n between committed {sorted(base)} and "
                f"current {sorted(new)}"]
    for n in shared:
        ref, cur = base[n], new[n]
        if not cur["parity"] or cur["stale_rows"] != 0:
            problems.append(
                f"n={n}: front answers no longer bitwise-equal to the "
                f"answering generation's materialisation "
                f"(stale_rows={cur['stale_rows']})")
            continue
        if not cur["diff"]["parity"]:
            problems.append(f"n={n}: /diff no longer matches the "
                            "brute-force cross-generation comparison")
        chunks = cur["diff"]["chunks"]
        for rep in cur["diff"]["passes"]:
            calls = rep["calls"]
            if calls[0]["old"] != chunks or \
                    any(c != {"new": 0, "old": 0} for c in calls[1:]):
                problems.append(
                    f"n={n} replica {rep['replica']}: diff chunk-fill "
                    f"profile drifted ({calls} vs one {chunks}-chunk "
                    "grouped pass then zero)")
        if any(r < 1 for r in cur["rebinds"]):
            problems.append(f"n={n}: a replica's pointer watcher never "
                            f"rebound (rebinds {cur['rebinds']})")
        ref_qps = ref["sustained"]["batched_qps"]
        cur_qps = cur["sustained"]["batched_qps"]
        if cur_qps < ref_qps * (1.0 - tol):
            problems.append(
                f"n={n}: sustained batched lookup QPS {ref_qps} -> "
                f"{cur_qps} (> {tol:.0%} regression)")
    return problems


def diff_obs(committed: dict, current: dict, tol: float) -> list:
    """Obs-report violations: bitwise parity and span shape are
    absolute, the enabled-path overhead is wall-gated.

    The instrumented solve must stay bitwise-identical to the
    uninstrumented one and the expected span counts must have fired
    (``spans_ok`` — a tracer that silently stopped emitting cannot
    pass). The enabled-path overhead fraction must stay within an
    absolute ``tol`` of the committed measurement (overheads are small
    ratios of noisy walls, so the slack is additive, not relative):
    committed 2% with ``tol`` 0.25 still fails a 30% current."""
    problems = []
    base = _points_by_n(committed)
    new = _points_by_n(current)
    shared = sorted(set(base) & set(new))
    if not shared:
        return [f"no shared n between committed {sorted(base)} and "
                f"current {sorted(new)}"]
    for n in shared:
        ref, cur = base[n], new[n]
        if not cur["identical"]:
            problems.append(
                f"n={n}: obs-on solve no longer bitwise-identical to the "
                "obs-off solve")
            continue
        if not cur["spans_ok"]:
            problems.append(
                f"n={n}: expected span counts missing "
                f"(spans={cur['spans']})")
        if cur["overhead_on"] > ref["overhead_on"] + tol:
            problems.append(
                f"n={n}: obs-on overhead {ref['overhead_on']} -> "
                f"{cur['overhead_on']} (> +{tol} absolute regression)")
        if cur["overhead_null"] > ref["overhead_null"] + tol:
            problems.append(
                f"n={n}: null-path overhead {ref['overhead_null']} -> "
                f"{cur['overhead_null']} (> +{tol} absolute regression)")
    return problems


def diff_screening(committed: dict, current: dict, tol: float) -> list:
    """Screening-report violations: oracle parity is absolute, the
    streamed-item reduction is the gated payoff.

    The screened solve must stay bitwise-identical to the unscreened
    oracle and stream no more items than it; both streamed profiles are
    deterministic, so at an equal iteration count any profile drift is a
    violation in itself. The items-reduction ratio must not shrink by
    more than ``tol`` against the committed report (wall time is
    informational — streamed items are the I/O the feature exists to
    save)."""
    problems = []
    base = _points_by_n(committed)
    new = _points_by_n(current)
    shared = sorted(set(base) & set(new))
    if not shared:
        return [f"no shared n between committed {sorted(base)} and "
                f"current {sorted(new)}"]
    for n in shared:
        ref, cur = base[n], new[n]
        if not cur["identical"]:
            problems.append(
                f"n={n}: screened result no longer bitwise-identical to "
                "the unscreened oracle")
            continue
        s, u = cur["screened"], cur["unscreened"]
        if s["items_streamed"] > u["items_streamed"]:
            problems.append(
                f"n={n}: screening streamed MORE items than the oracle "
                f"({s['items_streamed']} > {u['items_streamed']})")
            continue
        if cur["iterations"] != ref["iterations"]:
            print(f"note: n={n} iteration count "
                  f"{ref['iterations']} -> {cur['iterations']}; profile "
                  "comparison skipped, reduction ratio still gated")
        elif s["chunks_per_iter"] != ref["screened"]["chunks_per_iter"]:
            problems.append(
                f"n={n}: screened streamed-chunk profile drifted at equal "
                f"iteration count: {ref['screened']['chunks_per_iter']} -> "
                f"{s['chunks_per_iter']} (retirement got lazier?)")
        if cur["items_reduction"] < ref["items_reduction"] * (1.0 - tol):
            problems.append(
                f"n={n}: items-streamed reduction "
                f"{ref['items_reduction']} -> {cur['items_reduction']} "
                f"(screening payoff shrank > {tol:.0%})")
    return problems


def diff(committed: dict, current: dict, tol: float) -> list:
    """Return a list of human-readable violations (empty = gate passes)."""
    for kind, fn in (("serve", diff_serve), ("screening", diff_screening),
                     ("front", diff_front), ("obs", diff_obs)):
        if committed.get("bench") == kind or current.get("bench") == kind:
            if committed.get("bench") != current.get("bench"):
                return [f"report kind mismatch: committed "
                        f"{committed.get('bench')!r} vs current "
                        f"{current.get('bench')!r}"]
            return fn(committed, current, tol)
    problems = []
    base = _points_by_n(committed)
    new = _points_by_n(current)
    shared = sorted(set(base) & set(new))
    if not shared:
        return [f"no shared n between committed {sorted(base)} and "
                f"current {sorted(new)}"]
    for n in shared:
        for section in ("device", "host"):
            for config, entry in new[n][section].items():
                if not isinstance(entry, dict):
                    continue
                ref = base[n][section].get(config)
                if ref is None:
                    continue
                if entry["passes"] != ref["passes"]:
                    if entry["iterations"] == ref["iterations"]:
                        problems.append(
                            f"n={n} {section}/{config}: source passes "
                            f"{ref['passes']} -> {entry['passes']} at equal "
                            f"iteration count (a pass was reintroduced?)")
                    else:
                        print(f"note: n={n} {section}/{config} iterations "
                              f"{ref['iterations']} -> {entry['iterations']};"
                              f" pass/wall comparison skipped")
                        continue
                if (section, config) in WALL_GATED:
                    if entry["iterations"] != ref["iterations"]:
                        print(f"note: n={n} {section}/{config} iteration "
                              f"count changed; wall comparison skipped")
                        continue
                    if entry["wall_s"] > ref["wall_s"] * (1.0 + tol):
                        problems.append(
                            f"n={n} {section}/{config}: wall "
                            f"{ref['wall_s']}s -> {entry['wall_s']}s "
                            f"(> {tol:.0%} regression)")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("committed", help="committed BENCH_stream_passes.json")
    ap.add_argument("current", help="freshly measured report to check")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed wall-time regression fraction")
    args = ap.parse_args()
    committed = json.loads(pathlib.Path(args.committed).read_text())
    current = json.loads(pathlib.Path(args.current).read_text())
    problems = diff(committed, current, args.tol)
    for p in problems:
        print(f"BENCH REGRESSION: {p}")
    if problems:
        sys.exit(1)
    print(f"bench_diff: ok ({args.committed} vs {args.current}, "
          f"tol {args.tol:.0%})")


if __name__ == "__main__":
    main()
